"""Benchmark harness: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only table3,table5] [--fast]

Prints ``name,...`` CSV rows per table (see each module's docstring for
the mapping to the paper).  The roofline report additionally aggregates
the dry-run artifacts if present.
"""

from __future__ import annotations

import argparse
import sys
import time


def print_rows(name, rows):
    for r in rows:
        print(f"{name}," + ",".join(f"{k}={v}" for k, v in r.items()))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma list: table3,table4,table5,fig7,roofline")
    ap.add_argument("--fast", action="store_true",
                    help="smaller n (CI-sized)")
    args = ap.parse_args(argv)
    only = set(filter(None, args.only.split(",")))

    import jax
    jax.config.update("jax_enable_x64", True)

    from . import (fig7_scaling, roofline_report, table3_precision,
                   table4_dense, table5_sparse)

    t0 = time.time()
    if not only or "table3" in only:
        if args.fast:
            print_rows("table3", table3_precision.run(ns=(12, 16)))
        else:
            table3_precision.main()
    if not only or "table4" in only:
        if args.fast:
            print_rows("table4", table4_dense.run(ns=(12, 14)))
        else:
            table4_dense.main()
    if not only or "table5" in only:
        table5_sparse.main()
    if not only or "fig7" in only:
        if args.fast:
            print_rows("fig7", fig7_scaling.run(n=14, device_counts=(1, 2)))
        else:
            fig7_scaling.main()
    if not only or "roofline" in only:
        try:
            roofline_report.main()
        except Exception as e:
            print(f"# roofline report unavailable: {e}")
    print(f"# benchmarks done in {time.time() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
