"""Benchmark harness: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only table3,table5] [--fast]
    PYTHONPATH=src python -m benchmarks.run --check --only batch

Prints ``name,...`` CSV rows per table (see each module's docstring for
the mapping to the paper).  The roofline report additionally aggregates
the dry-run artifacts if present.  ``--check`` runs the tier-1 test suite
(scripts/tier1.sh) first and refuses to report perf numbers from a red
tree.
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time


def print_rows(name, rows):
    for r in rows:
        print(f"{name}," + ",".join(f"{k}={v}" for k, v in r.items()))


def _tier1_green() -> bool:
    script = os.path.join(os.path.dirname(__file__), "..", "scripts",
                          "tier1.sh")
    print("# --check: running tier-1 suite before benchmarking ...")
    r = subprocess.run(["bash", script, "-x"], capture_output=True, text=True)
    if r.returncode != 0:
        sys.stderr.write(r.stdout[-3000:] + r.stderr[-1000:])
        print("# tier-1 RED -- refusing to report benchmark numbers")
        return False
    print("# tier-1 green")
    return True


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma list: table3,table4,table5,fig7,batch,"
                         "solver_cache,batch_sharding,batch_complex,"
                         "batch_sparse,campaign,soak,autotune,roofline")
    ap.add_argument("--fast", action="store_true",
                    help="smaller n (CI-sized)")
    ap.add_argument("--check", action="store_true",
                    help="run tier-1 tests first; abort if red")
    args = ap.parse_args(argv)
    only = set(filter(None, args.only.split(",")))

    if args.check and not _tier1_green():
        return 1

    import jax
    jax.config.update("jax_enable_x64", True)

    from . import (autotune, batch_complex, batch_sharding, batch_sparse,
                   batch_throughput, campaign_resume, fig7_scaling,
                   roofline_report, serve_soak, solver_cache,
                   table3_precision, table4_dense, table5_sparse)

    t0 = time.time()
    if not only or "batch" in only:
        rows = batch_throughput.run(
            n=8, batch_sizes=(1, 8, 64) if args.fast else
            batch_throughput.BATCH_SIZES)
        print_rows("batch_throughput", rows)
    if not only or "solver_cache" in only:
        rows = solver_cache.run(
            n=12, requests=256, unique=8 if args.fast else 16,
            repeats=1 if args.fast else 3)
        print_rows("solver_cache", rows)
        if args.check and not solver_cache.check(rows):
            print("# solver_cache gate RED -- cache speedup below 2x")
            return 1
    if not only or "batch_sharding" in only:
        # measurement runs in its own subprocess (XLA_FLAGS is init-time),
        # so the forced 8-device mesh never leaks into this process
        rows = batch_sharding.run(
            sizes=batch_sharding.SIZES[1:] if args.fast
            else batch_sharding.SIZES,
            repeats=3 if args.fast else 7)
        print_rows("batch_sharding", rows)
        if args.check and not batch_sharding.check(rows):
            print("# batch_sharding gate RED -- sharded buckets below "
                  "0.9x jnp or not bit-identical")
            return 1
    if not only or "batch_complex" in only:
        # forced 8-device mesh in a subprocess, like batch_sharding
        rows = batch_complex.run(
            sizes=batch_complex.SIZES[:1] if args.fast
            else batch_complex.SIZES,
            repeats=3 if args.fast else 5)
        print_rows("batch_complex", rows)
        if args.check and not batch_complex.check(rows):
            print("# batch_complex gate RED -- complex pallas/sharded "
                  "buckets below 0.9x jnp or values diverged")
            return 1
    if not only or "batch_sparse" in only:
        # forced 8-device mesh in a subprocess, like batch_complex; fast
        # mode keeps only the gated density
        rows = batch_sparse.run(
            densities=batch_sparse.DENSITIES[-1:] if args.fast
            else batch_sparse.DENSITIES,
            repeats=3 if args.fast else 5)
        print_rows("batch_sparse", rows)
        if args.check and not batch_sparse.check(rows):
            print("# batch_sparse gate RED -- sparse pallas/sharded "
                  "buckets below 0.9x jnp or values diverged")
            return 1
    if not only or "campaign" in only:
        # forced 8-device meshes in subprocesses: direct-vs-campaign
        # throughput plus SIGKILL/resume bitwise identity
        rows = campaign_resume.run(
            n=campaign_resume.N_FAST if args.fast
            else campaign_resume.N_FULL,
            repeats=3 if args.fast else 5)
        print_rows("campaign_resume", rows)
        if args.check and not campaign_resume.check(rows):
            print("# campaign gate RED -- campaign below 0.9x direct "
                  "mesh throughput or resume not bitwise-identical")
            return 1
    if not only or "soak" in only:
        # two cold subprocesses sharing a compile-cache dir: Poisson
        # service soak + the no-retrace-storm cold-start property
        rows = serve_soak.run(
            requests=24 if args.fast else serve_soak.REQUESTS)
        print_rows("serve_soak", rows)
        if args.check and not serve_soak.check(rows):
            print("# serve_soak gate RED -- SLO, typed-shed, metrics "
                  "consistency, or warm-compile-cache cold start failed")
            return 1
    if not only or "autotune" in only:
        # tune + cold pickup in forced 8-device subprocesses; tuned must
        # never lose to the untuned default (1.0x floor), model error is
        # reported, not gated
        if args.fast:
            rows = autotune.run(n=autotune.N_FAST,
                                bucket=autotune.BUCKET_FAST,
                                top_k=1, repeats=1)
        else:
            rows = autotune.run()
        print_rows("autotune", rows)
        if args.check and not autotune.check(rows):
            print("# autotune gate RED -- tuned geometry lost to the "
                  "default or the persisted table was not picked up")
            return 1
    if not only or "table3" in only:
        if args.fast:
            print_rows("table3", table3_precision.run(ns=(12, 16)))
        else:
            table3_precision.main()
    if not only or "table4" in only:
        if args.fast:
            print_rows("table4", table4_dense.run(ns=(12, 14)))
        else:
            table4_dense.main()
    if not only or "table5" in only:
        table5_sparse.main()
    if not only or "fig7" in only:
        if args.fast:
            print_rows("fig7", fig7_scaling.run(n=14, device_counts=(1, 2)))
        else:
            fig7_scaling.main()
    if not only or "roofline" in only:
        try:
            roofline_report.main()
        except Exception as e:
            print(f"# roofline report unavailable: {e}")
    print(f"# benchmarks done in {time.time() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
