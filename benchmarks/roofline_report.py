"""Aggregate the dry-run JSONs into the roofline table (EXPERIMENTS.md).

Reads experiments/dryrun/*.json, emits CSV + a markdown table with the
three roofline terms, the dominant bottleneck, MODEL_FLOPS/HLO_FLOPs, and
the per-cell one-line interpretation.  When the autotuner has run
(``benchmarks/autotune.py`` or ``repro.launch.tune --report``), its
per-candidate predicted-vs-measured rows at
``experiments/dryrun/autotune/mispredict.json`` are appended as
``mispredict,...`` CSV -- the running scorecard of the tuner's cost
model against real kernel timings.
"""

from __future__ import annotations

import glob
import json
import os

OUT_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def load_cells(out_dir: str = OUT_DIR):
    cells = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def load_mispredicts(out_dir: str = OUT_DIR):
    """Autotuner predicted-vs-measured rows, worst model error first
    (empty when the tuner has not run)."""
    path = os.path.join(out_dir, "autotune", "mispredict.json")
    try:
        with open(path) as f:
            rows = json.load(f).get("rows", [])
    except OSError:
        return []
    return sorted(rows, key=lambda r: abs(1.0 - (r.get("mispredict_ratio")
                                                 or 1.0)), reverse=True)


def _suggestion(rec: dict) -> str:
    rl = rec.get("roofline", {})
    dom = rl.get("dominant")
    kind = "train" if rec["shape"].startswith("train") else (
        "prefill" if rec["shape"].startswith("prefill") else "decode")
    if dom == "collective":
        kinds = rec.get("cost_trip_aware", {}).get("coll_by_kind", {})
        top = max(kinds, key=kinds.get) if kinds else "?"
        return (f"cut {top} volume (coarser FSDP gather granularity / "
                "overlap with compute)")
    if dom == "memory":
        if kind == "decode":
            return "KV-cache read-bound: quantize cache / widen batch"
        return "increase arithmetic intensity (larger per-device batch)"
    return "compute-bound: already at the right end of the roofline"


def main(csv: bool = True):
    cells = load_cells()
    ok = [c for c in cells if c.get("status") == "ok"]
    skipped = [c for c in cells if str(c.get("status", "")).startswith("SKIP")]
    bad = [c for c in cells if c.get("status") not in ("ok",)
           and not str(c.get("status", "")).startswith("SKIP")]
    if csv:
        print("roofline,arch,shape,mesh,chips,compute_s,memory_s,"
              "collective_s,dominant,model_flops,hlo_flops,useful_ratio,"
              "mfu_bound")
        for c in sorted(ok, key=lambda c: (c["arch"], c["shape"],
                                           c["mesh"])):
            rl = c["roofline"]
            print(f"roofline,{c['arch']},{c['shape']},{c['mesh']},"
                  f"{c['chips']},{rl['compute_s']:.4g},{rl['memory_s']:.4g},"
                  f"{rl['collective_s']:.4g},{rl['dominant']},"
                  f"{c['model_flops']:.4g},{rl['flops']:.4g},"
                  f"{rl['useful_flops_ratio']:.3f},{rl['mfu_bound']:.4f}")
        print(f"# ok={len(ok)} skipped={len(skipped)} failed={len(bad)}")
        for c in bad:
            print(f"# FAILED {c.get('arch')} {c.get('shape')} "
                  f"{c.get('mesh')}")
        mis = load_mispredicts()
        if mis:
            print("mispredict,route,n,geometry,modeled_s,hlo_predicted_s,"
                  "predicted_s,measured_s,ratio")
            for r in mis:
                print(f"mispredict,{r['route']},{r['n']},{r['geometry']},"
                      f"{r['modeled_s']:.4g},{r['hlo_predicted_s']:.4g},"
                      f"{r['predicted_s']:.4g},{r['measured_s']:.4g},"
                      f"{r['mispredict_ratio']:.3f}")
    return ok, skipped, bad


def markdown_table(mesh: str = "single") -> str:
    ok, skipped, _ = main(csv=False)
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant |"
        " MODEL/HLO flops | MFU bound | next lever |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for c in sorted(ok, key=lambda c: (c["arch"], c["shape"])):
        if c["mesh"] != mesh:
            continue
        rl = c["roofline"]
        lines.append(
            f"| {c['arch']} | {c['shape']} | {rl['compute_s']:.3g} | "
            f"{rl['memory_s']:.3g} | {rl['collective_s']:.3g} | "
            f"{rl['dominant']} | {rl['useful_flops_ratio']:.2f} | "
            f"{rl['mfu_bound']:.3f} | {_suggestion(c)} |")
    for c in skipped:
        if c["mesh"] != mesh:
            continue
        lines.append(f"| {c['arch']} | {c['shape']} | - | - | - | "
                     f"SKIP(full-attention) | - | - | - |")
    return "\n".join(lines)


if __name__ == "__main__":
    main()
