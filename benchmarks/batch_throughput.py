"""Batched-engine throughput: perms/sec vs batch size (1 -> 256).

The SUperman headline is throughput, and the batch engine's whole point
is amortizing compilation + dispatch over a request stack.  This
benchmark times ``engine.permanent_batch`` on stacks of random n x n
matrices across batch sizes and reports perms/sec against the scalar
``engine.permanent`` loop baseline.

Acceptance gate (ISSUE 1): batch 64 of 8x8 real matrices must match the
scalar engine to rtol=1e-10 and deliver >= 5x the scalar perms/sec.

    PYTHONPATH=src python -m benchmarks.batch_throughput [--n 8]
    PYTHONPATH=src python -m benchmarks.run --only batch
"""

from __future__ import annotations

import argparse
import time

import numpy as np

BATCH_SIZES = (1, 2, 4, 8, 16, 32, 64, 128, 256)


def _time(fn, repeats: int):
    fn()  # warmup / compile
    t0 = time.time()
    for _ in range(repeats):
        fn()
    return (time.time() - t0) / repeats


def run(n: int = 8, batch_sizes=BATCH_SIZES, precision: str = "dq_acc",
        backend: str = "jnp", repeats: int = 5, seed: int = 0):
    from repro.core import engine

    rng = np.random.default_rng(seed)
    rows = []

    # scalar baseline: a 64-call loop through the scalar engine
    base_mats = rng.uniform(-1, 1, (64, n, n))
    scalar_vals = None

    def scalar_loop():
        nonlocal scalar_vals
        scalar_vals = np.array([engine.permanent(A, precision=precision,
                                                 backend=backend)
                                for A in base_mats])

    scalar_s = _time(scalar_loop, max(1, repeats // 2))
    scalar_pps = len(base_mats) / scalar_s
    rows.append({"n": n, "batch": "scalar", "perms_per_s": f"{scalar_pps:.0f}",
                 "speedup": "1.0"})

    for B in batch_sizes:
        mats = base_mats[:B] if B <= len(base_mats) \
            else rng.uniform(-1, 1, (B, n, n))
        batch_vals = None

        def batched():
            nonlocal batch_vals
            batch_vals = engine.permanent_batch(mats, precision=precision,
                                                backend=backend)

        dt = _time(batched, repeats)
        pps = B / dt
        if B <= len(base_mats):  # correctness vs the scalar engine
            np.testing.assert_allclose(batch_vals, scalar_vals[:B],
                                       rtol=1e-10)
        rows.append({"n": n, "batch": B, "perms_per_s": f"{pps:.0f}",
                     "speedup": f"{pps / scalar_pps:.1f}"})
    return rows


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=8)
    ap.add_argument("--precision", default="dq_acc")
    ap.add_argument("--backend", default="jnp", choices=("jnp", "pallas"))
    ap.add_argument("--repeats", type=int, default=5)
    args = ap.parse_args()

    import jax
    jax.config.update("jax_enable_x64", True)

    rows = run(n=args.n, precision=args.precision, backend=args.backend,
               repeats=args.repeats)
    for r in rows:
        print("batch_throughput," + ",".join(f"{k}={v}"
                                             for k, v in r.items()))
    at64 = next(r for r in rows if r["batch"] == 64)
    ok = float(at64["speedup"]) >= 5.0
    print(f"# batch=64 speedup {at64['speedup']}x vs scalar "
          f"({'OK' if ok else 'BELOW 5x TARGET'})")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
