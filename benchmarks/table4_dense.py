"""Paper Table 4: dense-kernel configuration comparison.

The paper's axes -- memory placement (x_shr/x_reg, A_shr/A_glb), CEG
on/off, matrix-specific rebuild -- map to our TPU-kernel axes:

  engine=seq          faithful Alg. 1 (no chunk parallelism)
  engine=chunked      Alg. 3, CEG-aligned power-of-2 chunks (jnp)
  engine=pallas       the TPU kernel (interpret on CPU), baseline mode
  engine=pallas-bat   window-batched matmul form (beyond-paper)

Wall-times here are CPU-interpreter numbers -- ordering is meaningful,
absolute speed is not (the TPU perf story lives in EXPERIMENTS.md Perf,
derived from lowered HLO).  n is capped for the same reason.
"""

from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from repro.core.oracle import perm_ryser_exact
from repro.core.ryser import perm_ryser_chunked, perm_ryser_seq
from repro.core.stepspace import Geometry
from repro.kernels.ops import permanent_pallas


def run(ns=(14, 16, 18), seed: int = 0):
    rng = np.random.default_rng(seed)
    rows = []
    for n in ns:
        A = rng.uniform(-1, 1, (n, n))
        exact = perm_ryser_exact(A) if n <= 16 else None
        engines = {
            "seq": lambda: float(perm_ryser_seq(jnp.asarray(A))),
            "chunked": lambda: float(perm_ryser_chunked(
                jnp.asarray(A), num_chunks=1024)),
            "pallas": lambda: float(permanent_pallas(
                A, mode="baseline", geometry=Geometry(64, 32, 16))),
            "pallas-bat": lambda: float(permanent_pallas(
                A, mode="batched", geometry=Geometry(64, 32, 16))),
        }
        base = None
        for name, fn in engines.items():
            t0 = time.time()
            val = fn()
            dt = time.time() - t0
            # re-time post-compilation
            t0 = time.time()
            val = fn()
            dt_warm = time.time() - t0
            if exact is not None:
                assert abs(val - exact) / max(abs(exact), 1e-12) < 1e-8, \
                    (n, name, val, exact)
            base = base or val
            rows.append({"n": n, "engine": name, "seconds": dt_warm,
                         "cold_seconds": dt, "value": val})
    return rows


def main(csv: bool = True):
    rows = run()
    if csv:
        print("table4,n,engine,seconds,cold_seconds")
        for r in rows:
            print(f"table4,{r['n']},{r['engine']},{r['seconds']:.4f},"
                  f"{r['cold_seconds']:.3f}")
    return rows


if __name__ == "__main__":
    main()
