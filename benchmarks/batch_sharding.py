"""Batch-sharded bucket throughput vs the single-device jnp bucket path.

ISSUE 3's tentpole: ``permanent_batch`` buckets can shard their leading
axis over ``core.distributed``'s mesh (``distributed_batch`` strategy --
data parallelism over matrices, each device owning whole permanents).
This benchmark measures perms/sec of a same-size dense bucket executed

* **jnp**  -- one vmapped device program on one device;
* **dist** -- the same bucket batch-axis-sharded over a forced 8-device
  host CPU mesh (``XLA_FLAGS=--xla_force_host_platform_device_count=8``).

and asserts the sharded values are BIT-IDENTICAL to the jnp ones (the
``distributed_batch`` contract).  Because XLA_FLAGS must be set before
jax initializes, the measurement runs in a subprocess; the parent parses
its CSV.

Acceptance gate (ISSUE 3): sharded throughput >= 0.9x the single-device
jnp path at the gated (n, B) -- parity-or-better; on real multi-chip
hardware (where devices do not share host cores) the expected regime is
>1x once buckets exceed the device count.

    PYTHONPATH=src python -m benchmarks.batch_sharding [--check]
    PYTHONPATH=src python -m benchmarks.run --only batch_sharding --check
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys

SPEEDUP_GATE = 0.9
DEVICES = 8
# (n, bucket) pairs to measure; the LAST row is the gated one (buckets
# must exceed the device count, and per-matrix work must be large enough
# that one device's intra-op parallelism stops scaling -- n=14 shards at
# >2x even on a shared-core host mesh; tiny n=10 work is dispatch-bound)
SIZES = ((10, 64), (12, 64), (14, 64))

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_WORKER = r"""
import time

import jax
jax.config.update("jax_enable_x64", True)
import numpy as np

from repro.core.solver import PermanentSolver, SolverConfig
from repro.launch.mesh import make_batch_mesh

sizes = {sizes!r}
repeats = {repeats}
mesh = make_batch_mesh({devices})
rng = np.random.default_rng({seed})


def best_time(solver, plan):
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        solver.execute(plan)
        best = min(best, time.perf_counter() - t0)
    return best


for n, B in sizes:
    mats = [rng.uniform(-1, 1, (n, n)) for _ in range(B)]
    jnp_solver = PermanentSolver(SolverConfig(
        backend="jnp", cache=False, preprocess=False))
    dist_solver = PermanentSolver(SolverConfig(
        backend="distributed", cache=False, preprocess=False),
        distributed_ctx=mesh)
    jnp_plan = jnp_solver.plan_batch(mats)
    dist_plan = dist_solver.plan_batch(mats)
    vj = jnp_solver.execute(jnp_plan)       # warm / compile
    vd = dist_solver.execute(dist_plan)
    bitwise = bool(np.array_equal(vj, vd))
    stats = dist_solver.stats()
    assert not stats["downgrades"], stats["downgrades"]
    tj = best_time(jnp_solver, jnp_plan)
    td = best_time(dist_solver, dist_plan)
    print(f"ROW,n={{n}},bucket={{B}},devices={{{devices}}},"
          f"jnp_perms_per_s={{B / tj:.0f}},dist_perms_per_s={{B / td:.0f}},"
          f"speedup={{tj / td:.2f}},bitwise={{int(bitwise)}}")
"""


def run(sizes=SIZES, devices: int = DEVICES, repeats: int = 7,
        seed: int = 0):
    """Measure in a forced-multi-device subprocess; returns CSV rows."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = _SRC + os.pathsep * bool(env.get("PYTHONPATH")) \
        + env.get("PYTHONPATH", "")
    code = _WORKER.format(sizes=tuple(sizes), repeats=repeats,
                          devices=devices, seed=seed)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=1200)
    if r.returncode != 0:
        raise RuntimeError(f"batch_sharding worker failed:\n"
                           f"{r.stdout[-2000:]}{r.stderr[-3000:]}")
    rows = []
    for line in r.stdout.splitlines():
        if not line.startswith("ROW,"):
            continue
        row = dict(kv.split("=", 1) for kv in line[4:].split(","))
        rows.append(row)
    if len(rows) != len(tuple(sizes)):
        raise RuntimeError(f"expected {len(tuple(sizes))} rows, parsed "
                           f"{len(rows)}:\n{r.stdout[-2000:]}")
    return rows


def check(rows) -> bool:
    """ISSUE-3 gate: sharded >= 0.9x jnp at the gated size, bit-identical
    everywhere."""
    ok = True
    for row in rows:
        if row["bitwise"] != "1":
            print(f"# batch_sharding: values NOT bit-identical at "
                  f"n={row['n']} bucket={row['bucket']} -- FAIL")
            ok = False
    gated = rows[-1]
    speedup = float(gated["speedup"])
    gate_ok = speedup >= SPEEDUP_GATE
    status = "OK" if gate_ok else "FAIL"
    print(f"# batch_sharding gate (n={gated['n']} bucket={gated['bucket']} "
          f"x{gated['devices']} devices): {speedup:.2f}x vs required "
          f"{SPEEDUP_GATE:.1f}x -- {status}")
    return ok and gate_ok


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=DEVICES)
    ap.add_argument("--repeats", type=int, default=7)
    ap.add_argument("--check", action="store_true",
                    help="enforce the >= 0.9x + bit-identity gate")
    args = ap.parse_args()

    rows = run(devices=args.devices, repeats=args.repeats)
    for r in rows:
        print("batch_sharding," + ",".join(f"{k}={v}" for k, v in r.items()))
    if args.check and not check(rows):
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
