"""Paper Table 3: precision-mode ladder on known-permanent matrices.

Matrices with all entries a have perm = n! * a^n exactly, so the relative
error of each precision mode is measurable.  The paper's n grows to 50 on
GPUs; on this CPU container n is capped (the cost is 2^{n-1}), but the
qualitative ordering -- DD worst by orders of magnitude; DQ/QQ/Kahan
comparable -- reproduces (see EXPERIMENTS.md Sec. vs-paper).
"""

from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from repro.core.oracle import all_ones_permanent
from repro.core.ryser import perm_ryser_chunked


def run(ns=(16, 20, 24), a: float = 0.5, num_chunks: int = 1024):
    rows = []
    for n in ns:
        exact = all_ones_permanent(n, a)
        A = jnp.full((n, n), a, dtype=jnp.float64)
        for mode in ("dd", "dq_fast", "dq_acc", "qq", "kahan"):
            t0 = time.time()
            val = float(perm_ryser_chunked(A, num_chunks=num_chunks,
                                           precision=mode))
            dt = time.time() - t0
            rel = abs(val - exact) / abs(exact)
            rows.append({"n": n, "mode": mode, "rel_err": rel,
                         "seconds": dt})
    return rows


def main(csv: bool = True):
    rows = run()
    if csv:
        print("table3,n,mode,rel_err,seconds")
        for r in rows:
            print(f"table3,{r['n']},{r['mode']},{r['rel_err']:.3e},"
                  f"{r['seconds']:.3f}")
    return rows


if __name__ == "__main__":
    main()
