"""The always-on permanent service: lanes, SLOs, and observability.

    PYTHONPATH=src python examples/service.py

``examples/quickstart.py`` covers the plan/execute solver; this is the
layer above it -- ``repro.serve.PermanentService``, the continuous-
batching loop that `launch/serve.py --mode permanent` (and `--soak`)
runs in production.  The lifecycle: configure lanes and budgets, warm
the compile caches, admit requests (every rejection is a typed shed,
never an exception from ``submit``), step/drain the loop, read one
metrics snapshot.
"""

import tempfile

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

from repro.core.solver import SolverConfig  # noqa: E402
from repro.serve import (LaneSpec, PermanentService, ServiceConfig,  # noqa: E402
                         ShedError, start_metrics_server)

rng = np.random.default_rng(0)
cache_dir = tempfile.mkdtemp(prefix="xla-cache-")

# --- 1. configure: lanes, budgets, warm-up ---------------------------------
# Two strict-priority lanes; each lane's slo_s doubles as the default
# per-request deadline.  The compile-cache dir persists XLA executables
# across process restarts; warmup_ns pre-compiles every power-of-two
# bucket geometry for n=10 so the first real bucket never retraces.
svc = PermanentService(
    SolverConfig(precision="dq_acc", backend="jnp"),
    ServiceConfig(max_batch=8,
                  lanes=(LaneSpec("interactive", 0, slo_s=2.0),
                         LaneSpec("bulk", 1, slo_s=30.0)),
                  max_queue_depth=64,
                  compile_cache_dir=cache_dir,
                  warmup_ns=(10,)))
wr = svc.warmup_report
print(f"warmup: {wr['geometries']} geometries in {wr['seconds']:.1f}s, "
      f"persistent compile cache: {wr['compile']}")

# --- 2. admit: priority lanes, typed shedding ------------------------------
# submit() returns a ticket immediately; shed tickets raise ShedError
# from result() with a typed reason (queue_full / cost_budget /
# deadline_expired / shutdown) -- load never surfaces as a bare crash.
bulk = [svc.submit(rng.uniform(-1, 1, (10, 10)), lane="bulk")
        for _ in range(6)]
urgent = svc.submit(rng.uniform(-1, 1, (10, 10)), lane="interactive")
doomed = svc.submit(rng.uniform(-1, 1, (10, 10)), lane="interactive",
                    deadline_s=0.0)          # expires before dispatch

# --- 3. the loop: continuous batching --------------------------------------
# step() dispatches one bucket whenever the device is free -- the
# interactive ticket rides the first bucket, bulk backfills its spare
# slots.  A real deployment calls step() forever; here we drain.
svc.step()
print(f"after one step: urgent done={urgent.done}, "
      f"{sum(t.done for t in bulk)}/6 bulk done (backfilled)")
svc.drain()
print(f"urgent perm = {urgent.result():+.6e}")
try:
    doomed.result()
except ShedError as e:
    print(f"doomed request shed as expected: {e}")

# --- 4. observe: one schema everywhere -------------------------------------
# The same snapshot backs the periodic log line, the soak benchmark
# gate, and the HTTP endpoint.  solver stats (cache, per-leaf device
# timings) are embedded verbatim.
snap = svc.snapshot()
req, lat = snap["requests"], snap["latency_s"]["overall"]
print(f"snapshot: admitted={req['admitted']} completed={req['completed']} "
      f"shed={req['shed']} | p50={lat['p50'] * 1e3:.0f}ms "
      f"p99={lat['p99'] * 1e3:.0f}ms | dispatches={snap['dispatches']}")
print(f"hottest kernel: "
      f"{max(snap['solver']['leaf_timings'].items(), key=lambda kv: kv[1]['total_s'])[0]}")
print(f"persistent compile cache now: {snap['compile_cache']}")

server = start_metrics_server(svc.snapshot, port=0)
import json  # noqa: E402
import urllib.request  # noqa: E402

with urllib.request.urlopen(
        f"http://127.0.0.1:{server.server_address[1]}/metrics") as r:
    print(f"GET /metrics -> schema {json.loads(r.read())['schema']}")
server.shutdown()
