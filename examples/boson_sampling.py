"""Boson-sampling output probabilities via permanents (paper Sec. 1).

The probability of detecting output configuration T given input S through
a linear-optical network U is  |perm(U_{S,T})|^2 / (prod s_i! prod t_j!).
This example builds a Haar-random unitary interferometer, extracts the
submatrices for a set of output patterns, and computes their probabilities
with the SUperman engine -- including the *batched complex* solver path
(one bucketed device program per submatrix size, complex values served by
the split re/im plane engines and, under ``backend="pallas"``, the
split-plane batch-grid kernel), something the original CUDA tool cannot
express.

    PYTHONPATH=src python examples/boson_sampling.py
"""

import jax

jax.config.update("jax_enable_x64", True)

import itertools  # noqa: E402

import numpy as np  # noqa: E402

from repro.core import engine  # noqa: E402
from repro.core.solver import PermanentSolver, SolverConfig  # noqa: E402

M_MODES = 12      # interferometer modes
N_PHOTONS = 6     # photons (submatrix size)


def haar_unitary(m: int, rng) -> np.ndarray:
    z = (rng.normal(size=(m, m)) + 1j * rng.normal(size=(m, m))) / np.sqrt(2)
    q, r = np.linalg.qr(z)
    return q * (np.diag(r) / np.abs(np.diag(r)))


def main():
    rng = np.random.default_rng(42)
    U = haar_unitary(M_MODES, rng)
    in_modes = list(range(N_PHOTONS))        # photons in the first n modes

    # sample some collision-free output patterns
    patterns = list(itertools.combinations(range(M_MODES), N_PHOTONS))
    rng.shuffle(patterns)
    patterns = patterns[:32]

    # --- engine path: one permanent at a time (full preprocessing) -----
    probs = []
    for T in patterns[:8]:
        sub = U[np.ix_(in_modes, T)]
        amp = engine.permanent(sub, precision="kahan")
        probs.append(abs(amp) ** 2)
    print("per-pattern probabilities (engine):")
    for T, p in zip(patterns[:8], probs):
        print(f"  T={T}: {p:.3e}")

    # --- batched complex solver path (ISSUE 4): ONE bucketed device ----
    # program for the whole pattern set, served by the split re/im plane
    # batch-grid Pallas kernel -- no pallas->jnp downgrade for complex
    subs = [U[np.ix_(in_modes, T)] for T in patterns]
    psolver = PermanentSolver(SolverConfig(precision="kahan",
                                           backend="pallas"))
    plan = psolver.plan_batch(subs)
    print(f"\n{plan.summary()}")
    amps, reports = psolver.execute(plan, return_report=True)
    tags = sorted({t for r in reports for t in r.dispatch})
    assert not any("->" in t for t in tags), \
        f"complex buckets must not downgrade: {tags}"
    print(f"dispatch tags: {tags}")
    bprobs = np.abs(np.asarray(amps)) ** 2
    print(f"batched over {len(patterns)} patterns: "
          f"sum p = {bprobs.sum():.4f} (partial space)")
    # consistency between paths
    np.testing.assert_allclose(bprobs[:8], probs, rtol=1e-8)
    print("engine vs batched solver paths agree to 1e-8  OK")

    # --- solver path: resampled patterns hit the result cache ----------
    # A sampling chain revisits output patterns; PermanentSolver's
    # content-hash cache resolves repeats without touching the device.
    solver = PermanentSolver(SolverConfig(precision="kahan"))
    draws = [patterns[i] for i in rng.integers(0, 8, 64)]
    stream = [U[np.ix_(in_modes, T)] for T in draws]
    svals = solver.execute(solver.plan_batch(stream))
    cs = solver.stats()["cache"]
    print(f"\nresampled stream of {len(stream)} submatrices: "
          f"{cs['hits']} cache hits / {cs['misses']} misses "
          f"({solver.stats()['device_dispatches']} device dispatches)")
    np.testing.assert_allclose(
        np.abs(svals) ** 2, [bprobs[patterns.index(T)] for T in draws],
        rtol=1e-8)
    print("solver path agrees with batched path  OK")

    # total over ALL collision-free patterns for a smaller instance:
    # probabilities must sum to <= 1 (remaining mass = collision events)
    m_small, n_small = 8, 4
    U2 = haar_unitary(m_small, rng)
    total = 0.0
    for T in itertools.combinations(range(m_small), n_small):
        sub = U2[np.ix_(list(range(n_small)), T)]
        total += abs(engine.permanent(sub, precision="kahan")) ** 2
    print(f"\nsum over all collision-free outputs (m={m_small}, "
          f"n={n_small}): {total:.4f} <= 1  "
          f"({'OK' if total <= 1.0 + 1e-9 else 'VIOLATION'})")


if __name__ == "__main__":
    main()
