"""Counting perfect matchings of bipartite graphs via 0/1 permanents
(paper Sec. 1: dimers, cycle covers, Nash-equilibrium structures).

Demonstrates the sparse pipeline end-to-end: DM elimination strips
edges that belong to no perfect matching, Forbert-Marx compression
collapses low-degree vertices, and the count is exact (integer).

    PYTHONPATH=src python examples/sparse_matchings.py
"""

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

from repro.core import engine  # noqa: E402
from repro.core.decompose import dm_eliminate, fm_decompose  # noqa: E402
from repro.core.oracle import perm_bigint  # noqa: E402


def grid_graph_biadjacency(rows: int, cols: int) -> np.ndarray:
    """Bipartite double cover of a rows x cols grid: matchings of the
    cover correspond to dimer configurations."""
    n = rows * cols
    A = np.zeros((n, n))
    for r in range(rows):
        for c in range(cols):
            u = r * cols + c
            A[u, u] = 1
            if c + 1 < cols:
                A[u, u + 1] = 1
                A[u + 1, u] = 1
            if r + 1 < rows:
                A[u, u + cols] = 1
                A[u + cols, u] = 1
    return A


def main():
    rng = np.random.default_rng(3)

    # --- 1. structured graph ------------------------------------------
    A = grid_graph_biadjacency(4, 4)
    count = round(engine.permanent(A))
    exact = perm_bigint(A.astype(np.int64))
    print(f"4x4 grid cover: {count} perfect matchings "
          f"(exact bigint oracle: {exact}) "
          f"{'OK' if count == exact else 'MISMATCH'}")

    # --- 2. random sparse bipartite graph + preprocessing detail -------
    n, p = 22, 0.18
    G = (rng.uniform(0, 1, (n, n)) < p).astype(float)
    G[np.arange(n), np.arange(n)] = 1.0   # ensure a perfect matching
    Gdm, removed = dm_eliminate(G)
    leaves = fm_decompose(Gdm)
    val, report = engine.permanent(G, return_report=True)
    exact = perm_bigint(G.astype(np.int64))
    print(f"\nrandom bipartite n={n}, |E|={int(G.sum())}:")
    print(f"  DM removed {removed} edges in no perfect matching")
    print(f"  Forbert-Marx left {len(leaves)} leaves, sizes "
          f"{sorted(set(l.matrix.shape[0] for l in leaves), reverse=True)}")
    print(f"  matchings = {round(val)} (exact {exact}) "
          f"{'OK' if round(val) == exact else 'MISMATCH'}")

    # --- 3. a graph with NO perfect matching ---------------------------
    H = np.zeros((6, 6))
    H[:, :4] = 1.0  # two right-vertices isolated
    print(f"\nKoenig-deficient graph: {round(engine.permanent(H))} "
          "matchings (structurally singular, detected by DM)")

    # --- 4. triangular: only the diagonal survives DM -------------------
    T = np.tril(np.ones((8, 8)))
    Tdm, rem = dm_eliminate(T)
    print(f"\nlower-triangular: DM removed {rem}/{int(T.sum()) - 8} "
          f"off-diagonal entries; perm = {round(engine.permanent(T))}")


if __name__ == "__main__":
    main()
