"""Preemption-safe computation of one large permanent (paper Sec. 6.3).

A single n x n Ryser permanent costs n * 2^{n-1} operations -- at n = 50
that is days of device time, far beyond any scheduler's preemption
horizon.  This example walks the full campaign lifecycle the plan/execute
stack provides for exactly that regime, scaled down to n = 14 so it runs
in seconds on CPU:

1. PLAN   -- ``SolverConfig.campaign_threshold`` routes the matrix to the
             ``step_sharded`` route; the plan records the resumable slice
             decomposition (a ``CampaignSpec``), independent of the
             device count.
2. RUN    -- the executor's ``CampaignBackend`` runs slices in
             device-count-sized waves, checkpointing twofloat partials
             after each wave.
3. KILL   -- we simulate preemption with ``campaign_max_waves``: the
             executor raises ``CampaignPaused`` with work still pending.
             (A real SIGKILL behaves identically -- see
             tests/test_campaign.py.)
4. RESUME -- a *fresh* solver pointed at the same checkpoint finishes the
             pending slices and returns the value.
5. CHECK  -- the resumed value is bitwise-identical to an uninterrupted
             run, and matches the direct engine.

    PYTHONPATH=src python examples/large_permanent.py
"""

import jax

jax.config.update("jax_enable_x64", True)

import os  # noqa: E402
import tempfile  # noqa: E402

import numpy as np  # noqa: E402

from repro.core import engine  # noqa: E402
from repro.core.distributed import CampaignPaused  # noqa: E402
from repro.core.solver import PermanentSolver, SolverConfig  # noqa: E402

N = 14

rng = np.random.default_rng(0)
A = rng.uniform(0.2, 1.2, (N, N))

with tempfile.TemporaryDirectory() as tmp:
    ckpt = os.path.join(tmp, "campaign.npz")
    config = SolverConfig(
        precision="dq_acc",
        preprocess=False,            # campaign the matrix as-is
        campaign_threshold=-1.0,     # force the step_sharded route
        campaign_slices=16, campaign_lanes=64,
        campaign_checkpoint=ckpt)

    # 1. PLAN: inspect the recorded slice decomposition before any
    #    device work happens
    solver = PermanentSolver(config.replace(campaign_max_waves=2))
    solver.campaign_progress = lambda s: print(
        f"   wave checkpointed: {s.fraction_done():6.1%} done")
    plan = solver.plan(A)
    leaf = plan.leaves[0]
    print(f"1. plan: {plan.summary()}")
    print(f"   route={leaf.route} spec={leaf.campaign}")

    # 2.+3. RUN under a 2-wave budget, then get preempted
    print("2. running with a 2-wave budget ...")
    try:
        solver.execute(plan)
        raise AssertionError("expected the wave budget to preempt the run")
    except CampaignPaused as e:
        print(f"3. preempted: {e}")

    # 4. RESUME: a fresh solver (nothing shared but the checkpoint file)
    print("4. resuming from the checkpoint with a fresh solver ...")
    resumed = PermanentSolver(config)
    value = resumed.execute(resumed.plan(A))

    # 5. CHECK: bitwise vs an uninterrupted campaign, close vs the engine
    uninterrupted = PermanentSolver(
        config.replace(campaign_checkpoint=None))
    direct = uninterrupted.execute(uninterrupted.plan(A))
    oracle = engine.permanent(A, precision="dq_acc", preprocess=False)
    print(f"5. perm(A)      = {value:+.17e}")
    print(f"   uninterrupted= {direct:+.17e}  "
          f"bitwise: {np.float64(value) == np.float64(direct)}")
    print(f"   engine       = {oracle:+.17e}  "
          f"rel.err: {abs(value - oracle) / abs(oracle):.2e}")
    assert np.float64(value) == np.float64(direct)
    assert abs(value - oracle) / abs(oracle) < 1e-12
    print("OK")
