"""Quickstart: compute matrix permanents the SUperman way.

    PYTHONPATH=src python examples/quickstart.py

Covers the public API surface in ~80 lines: dense/sparse/complex
permanents, precision modes, preprocessing, the Pallas TPU kernel
(interpret-mode on CPU), batched throughput via ``permanent_batch``,
and exactness checks against closed forms.
"""

import jax

jax.config.update("jax_enable_x64", True)  # f64 precision semantics on CPU

import numpy as np  # noqa: E402

from repro.core import engine  # noqa: E402
from repro.core.oracle import all_ones_permanent  # noqa: E402

rng = np.random.default_rng(0)

# --- 1. dense real matrix -------------------------------------------------
A = rng.uniform(-1, 1, (16, 16))
val = engine.permanent(A)
print(f"perm(random 16x16)            = {val:+.12e}")

# --- 2. precision modes (paper Table 3) -----------------------------------
B = np.full((16, 16), 0.5)
exact = all_ones_permanent(16, 0.5)
for mode in ("dd", "dq_acc", "kahan"):
    v = engine.permanent(B, precision=mode)
    print(f"perm(0.5 * ones) [{mode:7s}]   rel.err = "
          f"{abs(v - exact) / exact:.2e}")

# --- 3. sparse matrix with preprocessing (paper Sec. 4) -------------------
S = rng.uniform(0.5, 1.5, (20, 20)) * (rng.uniform(0, 1, (20, 20)) < 0.25)
v, report = engine.permanent(S, return_report=True)
print(f"perm(sparse 20x20)            = {v:+.12e}")
print(f"  DM removed {report.dm_removed} nonzeros; "
      f"Forbert-Marx left {report.fm_leaves} leaves "
      f"(sizes {report.leaf_sizes[:5]} ...)")

# --- 4. complex matrix (boson-sampling style) ------------------------------
C = rng.normal(size=(12, 12)) + 1j * rng.normal(size=(12, 12))
v = engine.permanent(C)
print(f"perm(complex 12x12)           = {v:+.6e}")

# --- 5. the Pallas TPU kernel (interpret-mode on CPU) ----------------------
v_pallas = engine.permanent(A, backend="pallas", preprocess=False)
print(f"pallas vs jnp                 = {v_pallas:+.12e} "
      f"(delta {abs(v_pallas - val):.2e})")

# --- 6. 0/1 matrices count perfect matchings -------------------------------
M = np.array([[1, 1, 0, 0],
              [1, 1, 1, 0],
              [0, 1, 1, 1],
              [0, 0, 1, 1]], dtype=float)
print(f"perfect matchings of the path-ish graph = "
      f"{round(engine.permanent(M))}")

# --- 7. batched stacks: one device program per size bucket -----------------
# A boson-sampling-style workload asks for permanents of MANY submatrices;
# permanent_batch buckets same-size leaves after DM/FM preprocessing and
# dispatches each bucket as a single vmapped program (sizes may be ragged,
# dense and sparse can mix in one call).
import time  # noqa: E402

stack = rng.uniform(-1, 1, (64, 8, 8))
vals = engine.permanent_batch(stack)          # warm up the bucket program
t0 = time.time()
vals = engine.permanent_batch(stack)
dt = time.time() - t0
print(f"perm of 64 stacked 8x8 in one dispatch: {64 / dt:,.0f} perms/s "
      f"(first: {vals[0]:+.6e})")
