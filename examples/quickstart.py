"""Quickstart: compute matrix permanents the SUperman way.

    PYTHONPATH=src python examples/quickstart.py

The public API is the plan/execute lifecycle of ``PermanentSolver``:
``solver.plan(A)`` reifies the paper's Alg.-4 dispatch (type sniff ->
DM/FM preprocessing -> dense/sparse routing -> size bucketing) as an
inspectable, serializable ``ExecutionPlan``; ``solver.execute(plan)``
dispatches it through the backend registry (jnp / pallas / distributed)
and the solver's content-hash result cache; ``solver.submit()`` /
``flush()`` run the async request queue serving uses.  The legacy
``engine.permanent`` / ``permanent_batch`` free functions remain as
stateless one-shot wrappers.
"""

import jax

jax.config.update("jax_enable_x64", True)  # f64 precision semantics on CPU

import numpy as np  # noqa: E402

from repro.core import engine  # noqa: E402
from repro.core.oracle import all_ones_permanent  # noqa: E402
from repro.core.solver import PermanentSolver, SolverConfig  # noqa: E402

rng = np.random.default_rng(0)

# --- 1. the plan/execute lifecycle -----------------------------------------
solver = PermanentSolver(SolverConfig(precision="dq_acc", backend="jnp"))

A = rng.uniform(-1, 1, (16, 16))
plan = solver.plan(A)               # pure planning: no device work yet
print(f"plan: {plan.summary()}")
val = solver.execute(plan)          # dispatch through the backend registry
print(f"perm(random 16x16)            = {val:+.12e}")

# --- 2. plans are inspectable and serializable -----------------------------
S = rng.uniform(0.5, 1.5, (20, 20)) * (rng.uniform(0, 1, (20, 20)) < 0.25)
splan = solver.plan(S)
blob = splan.to_json()              # leaves, routes, buckets, cost estimate
print(f"sparse 20x20 plan: {len(blob['leaves'])} leaves, "
      f"{len(blob['buckets'])} buckets, "
      f"est {blob['estimated_steps']:.3g} Ryser steps")
v, report = solver.execute(splan, return_report=True)
print(f"perm(sparse 20x20)            = {v:+.12e}")
print(f"  DM removed {report.dm_removed} nonzeros; "
      f"Forbert-Marx left {report.fm_leaves} leaves "
      f"(sizes {report.leaf_sizes[:5]} ...)")

# --- 3. the result cache: repeated submatrices skip the device -------------
# Boson-sampling pipelines resample overlapping submatrices; the solver
# memoizes post-DM/FM leaves by content hash.
solver.execute(solver.plan(A))      # same matrix again -> pure cache hit
cs = solver.stats()["cache"]
print(f"cache after re-solve: {cs['hits']} hits / {cs['misses']} misses "
      f"(hit rate {cs['hit_rate']:.0%})")

# --- 4. the async request queue: serving traffic ---------------------------
# submit() accumulates requests in size buckets; a bucket flushes when it
# reaches queue_max_batch or its oldest request ages past the deadline.
# (For production traffic, the layer above this queue is
# repro.serve.PermanentService -- continuous batching, priority lanes,
# typed load-shedding, SLO metrics; see examples/service.py.)
qsolver = PermanentSolver(SolverConfig(queue_max_batch=4,
                                       queue_max_delay_s=0.5))
reqs = [qsolver.submit(rng.uniform(-1, 1, (8, 8))) for _ in range(10)]
qsolver.flush()                     # drain the ragged tail
print(f"queued 10 requests -> {qsolver.flushes} batched flushes; "
      f"first value {reqs[0].result():+.6e}")

# --- 5. precision modes (paper Table 3) -----------------------------------
B = np.full((16, 16), 0.5)
exact = all_ones_permanent(16, 0.5)
for mode in ("dd", "dq_acc", "kahan"):
    psolver = PermanentSolver(precision=mode)
    v = psolver.execute(psolver.plan(B))
    print(f"perm(0.5 * ones) [{mode:7s}]   rel.err = "
          f"{abs(v - exact) / exact:.2e}")

# --- 6. complex matrices and the Pallas TPU kernel -------------------------
C = rng.normal(size=(12, 12)) + 1j * rng.normal(size=(12, 12))
print(f"perm(complex 12x12)           = {engine.permanent(C):+.6e}")
v_pallas = engine.permanent(A, backend="pallas", preprocess=False)
print(f"pallas vs jnp                 = {v_pallas:+.12e} "
      f"(delta {abs(v_pallas - val):.2e})")

# --- 7. legacy one-shot wrappers + batched stacks --------------------------
M = np.array([[1, 1, 0, 0],
              [1, 1, 1, 0],
              [0, 1, 1, 1],
              [0, 0, 1, 1]], dtype=float)
print(f"perfect matchings of the path-ish graph = "
      f"{round(engine.permanent(M))}")

import time  # noqa: E402

stack = rng.uniform(-1, 1, (64, 8, 8))
vals = engine.permanent_batch(stack)          # warm up the bucket program
t0 = time.time()
vals = engine.permanent_batch(stack)
dt = time.time() - t0
print(f"perm of 64 stacked 8x8 in one dispatch: {64 / dt:,.0f} perms/s "
      f"(first: {vals[0]:+.6e})")
