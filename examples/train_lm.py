"""End-to-end driver: train a (reduced) assigned-arch LM for a few hundred
steps on CPU with checkpoint/resume, then serve a few tokens from it.

    PYTHONPATH=src python examples/train_lm.py [--arch mixtral-8x22b]

This is the deliverable-(b) end-to-end example: the same launch/train.py
code path scales to the production mesh; here it runs the reduced config
so it finishes on one CPU in minutes.
"""

import argparse
import tempfile

from repro.configs import ARCH_IDS
from repro.launch.serve import run_serving
from repro.launch.train import run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as ckpt:
        print(f"=== training {args.arch} (reduced) for {args.steps} steps "
              f"with checkpointing ===")
        _, _, history = run_training(
            args.arch, steps=args.steps, seq=128, global_batch=8,
            reduced=True, ckpt_dir=ckpt, ckpt_every=100, lr=1e-3)
        first, last = history[0][1], history[-1][1]
        print(f"loss: {first:.3f} -> {last:.3f}")

        print("\n=== resuming from the checkpoint for 20 more steps ===")
        run_training(args.arch, steps=args.steps + 20, seq=128,
                     global_batch=8, reduced=True, ckpt_dir=ckpt,
                     ckpt_every=100, lr=1e-3)

    print("\n=== serving a few tokens (prefill + greedy decode) ===")
    out = run_serving(args.arch, prompt_len=32, gen=8, batch=2,
                      reduced=True)
    print(f"decoded: {out['tokens'].tolist()}")
    print(f"kv policy: {out['kv_policy']}; "
          f"{out['tok_per_s']:.1f} tok/s on this host")


if __name__ == "__main__":
    main()
